"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches JAX device state (the dry-run must set XLA_FLAGS before any init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke paths (1x1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_context(mesh):
    """Ambient-mesh context manager across JAX versions.

    ``jax.set_mesh`` (newer releases) / ``jax.sharding.use_mesh``
    (transitional) when available; otherwise the :class:`Mesh` itself,
    which is a context manager on older lines (0.4.x).  Usage::

        with mesh_context(mesh):
            ...
    """
    import jax.sharding

    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod axis folds into DP)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
