"""Sharding rules: FSDP over ``data`` x TP over ``model`` x DP over ``pod``.

Named rules with divisibility fallbacks: a dimension is only sharded when it
divides evenly by the axis size; otherwise the rule degrades gracefully
(replicate that dim) instead of failing — e.g. internvl2's 14 attention
heads and odd 151655 vocab replicate over ``model`` while its FFN shards.

Conventions:
  * weights:     second/contract dim -> model (TP), other large dim -> data
                 (FSDP: all-gather params per block, reduce-scatter grads)
  * MoE experts: expert dim -> model (EP), d_model dim -> data
  * activations: batch -> (pod, data), heads/ffn/expert dims -> model
  * KV caches:   batch -> (pod, data); kv-head dim -> model when divisible
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.launch.mesh import dp_axes
from repro.models.config import ArchConfig
from repro.models.model import ActSharding


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    n = _axis_size(mesh, axes)
    return dim % n == 0 and dim >= n


ATTN_Q = ("wq", "bq")
ATTN_KV = ("wk", "wv", "bk", "bv")
ATTN_O = ("wo",)


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               cfg: Optional[ArchConfig] = None,
               dp_override=None) -> PS:
    """PartitionSpec for one parameter, by name pattern + divisibility.

    Attention projections are TP-sharded over ``model`` only when the HEAD
    count divides the axis — otherwise XLA lands the sharding on head_dim
    and every score einsum psums a (B,H,Sq,Sk) fp32 tensor (measured: 3 x
    144 GiB/step on gemma-2b before this rule).  Head-indivisible archs
    replicate attention weights over ``model`` (they are small) and keep
    TP for the FFN.
    """
    dp = dp_axes(mesh) if dp_override is None else dp_override
    dp = dp if dp else None
    stacked = "cycles" in path or "layers" in path  # leading cycle dim
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape

    def ok(i, axes):
        return _fits(core[i], mesh, axes)

    name = path.rsplit("/", 1)[-1]
    model_n = mesh.shape["model"]
    heads_div = cfg is not None and cfg.num_heads % model_n == 0
    kv_div = cfg is not None and cfg.num_kv_heads % model_n == 0

    if len(core) == 1:
        return PS(*lead, None)                      # norms, biases, lam

    if name in ("embed", "head"):
        v_dim, d_dim = (0, 1) if name == "embed" else (1, 0)
        spec = [None, None]
        if ok(v_dim, "model"):
            spec[v_dim] = "model"
        if ok(d_dim, dp):
            spec[d_dim] = dp
        return PS(*spec)

    if name in ("w_router",):
        return PS(*lead, dp if ok(0, dp) else None, None)

    if len(core) == 3:                              # MoE experts
        # EP over model on the expert dim.  The second shard goes on the
        # FFN-hidden dim over data — NOT on d_model: FSDP-gathering 450GB
        # of expert weights per microbatch measured 88 TiB/step of
        # all-gathers on qwen3-moe; sharding F instead turns that into
        # one (E/m, C, D) reduce-scatter per layer (~30x less traffic),
        # and per-device weight storage still fits.
        e = "model" if ok(0, "model") else None
        f_dim = 2 if name in ("w_gate", "w_up") else 1   # w_down: (E, F, D)
        spec = [e, None, None]
        if ok(f_dim, dp):
            spec[f_dim] = dp
        return PS(*lead, *spec)

    if len(core) == 2:
        if name in ATTN_Q or name in ATTN_KV or name in ATTN_O:
            head_ok = kv_div if name in ATTN_KV else heads_div
            out_side = name in ATTN_O
            i, j = (0, 1) if out_side else (1, 0)
            spec = [None, None]
            if head_ok and ok(i, "model"):
                spec[i] = "model"
            if ok(j, dp):
                spec[j] = dp
            return PS(*lead, *spec)
        # contract-dim heuristic: output-side mats have the model-parallel
        # dim FIRST; input-side mats have it LAST.
        out_side = name in ("w_down", "w_out", "w_shared_down")
        i, j = (0, 1) if out_side else (1, 0)
        spec = [None, None]
        if ok(i, "model"):
            spec[i] = "model"
        if ok(j, dp):
            spec[j] = dp
        return PS(*lead, *spec)

    return PS(*lead, *(None,) * len(core))


ZERO1_MAX_PARAMS = 2e9   # replicate weights over dp below this size


def zero_policy(cfg: Optional[ArchConfig]) -> str:
    """ZeRO-1 (weights replicated over dp, optimizer states sharded) for
    small models: re-gathering a 2.5B model's weights every microbatch cost
    252 GiB/step of collectives on gemma-2b; replicating them costs ~5 GiB
    of HBM and one gradient reduction.  Big models need ZeRO-3."""
    if cfg is None:
        return "zero3"
    return "zero1" if cfg.n_params() <= ZERO1_MAX_PARAMS else "zero3"


def params_shardings(abstract, mesh: Mesh, cfg: Optional[ArchConfig] = None,
                     policy: Optional[str] = None):
    """Tree of NamedShardings matching an abstract param tree.

    ``policy``: "zero3" shards weights over dp (default for big models),
    "zero1" replicates weights over dp (optimizer states should be built
    with policy="zero3" regardless — they are only touched once per step).
    """
    policy = policy or zero_policy(cfg)
    dp_override = () if policy == "zero1" else None
    # sequence-parallel archs (head-indivisible) under ZeRO-1: the model
    # axis is busy sharding the sequence, so TP-sharding FFN weights only
    # causes per-layer resharding; replicate everything except the
    # embedding (vocab stays TP for the LM head).
    seq_par_z1 = (policy == "zero1" and cfg is not None
                  and cfg.num_heads % mesh.shape["model"] != 0)

    def visit(path, leaf):
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        name = pstr.rsplit("/", 1)[-1]
        if seq_par_z1 and name not in ("embed", "head"):
            return NamedSharding(mesh, PS(*(None,) * len(leaf.shape)))
        return NamedSharding(
            mesh, param_spec(pstr, leaf.shape, mesh, cfg,
                             dp_override=dp_override))

    return jax.tree_util.tree_map_with_path(visit, abstract)


def act_sharding(cfg: ArchConfig, mesh: Mesh, batch: int,
                 seq: Optional[int] = None) -> ActSharding:
    """Activation constraints: TP on heads when they divide ``model``;
    otherwise **sequence parallelism** — shard the seq dim over ``model``
    (attention/FFN/norms are row-wise; only K/V need a small all-gather).
    Replicating attention on the model axis instead costs ~16x its FLOPs
    (measured 8e13 extra FLOPs/dev on gemma-2b train_4k)."""
    dp = dp_axes(mesh)
    model_n = mesh.shape["model"]
    bdim = dp if batch % _axis_size(mesh, dp) == 0 else None
    heads_div = cfg.num_heads % model_n == 0
    # sequence parallelism requires every mixer to be row-wise in seq:
    # recurrent kinds (rglru/mlstm/slstm) scan over the sequence, and the
    # chunked-attention prefill path maps over seq chunks — both reshard
    # every step if seq is model-sharded (measured 16x regression on
    # xlstm train_4k, 7x on llama3.2 prefill_32k).  Callers therefore only
    # pass ``seq`` for dense-attention TRAIN shapes.
    attn_only = all(k in ("attn", "swa") for k in cfg.layer_kinds())
    seq_par = ((not heads_div) and attn_only and seq is not None
               and seq % model_n == 0)
    sdim = "model" if seq_par else None
    heads = "model" if heads_div else None
    ffn_div = cfg.d_ff % model_n == 0 and cfg.d_ff > 0
    # LM head: vocab TP whenever the vocab divides (the seq all-gather it
    # implies is ~256MB vs multi-GB seq-sharded full-vocab logits)
    vocab_div = cfg.vocab_size % model_n == 0
    kv_div = cfg.num_kv_heads % model_n == 0
    return ActSharding(
        hidden=PS(bdim, sdim, None),
        heads=PS(bdim, sdim, heads, None),
        kv=PS(bdim, sdim, "model" if kv_div else None, None),
        ffn=PS(bdim, sdim, "model" if (ffn_div and not seq_par) else None),
        expert=PS("model", None, None) if cfg.moe else None,
        logits=PS(bdim, sdim if not vocab_div else None,
                  "model" if vocab_div else None),
        moe_mesh=mesh if cfg.moe else None,
        moe_dp_axes=dp if cfg.moe else (),
    )


def batch_shardings(cfg: ArchConfig, mesh: Mesh, batch: int,
                    kind: str) -> Dict[str, Any]:
    """Shardings for the input batch pytree."""
    dp = dp_axes(mesh)
    bdim = dp if batch % _axis_size(mesh, dp) == 0 else None
    out: Dict[str, Any] = {
        "tokens": NamedSharding(mesh, PS(bdim, None)),
    }
    if kind == "train":
        out["labels"] = NamedSharding(mesh, PS(bdim, None))
    if cfg.frontend == "patch":
        out["embeds"] = NamedSharding(mesh, PS(bdim, None, None))
    if cfg.frontend == "frames":
        out["frames"] = NamedSharding(mesh, PS(bdim, None, None))
    return out


def cache_shardings(cache_abstract, cfg: ArchConfig, mesh: Mesh, batch: int,
                    for_decode: bool = True):
    """Shardings for the cache pytree (batch over dp, kv/state dims over
    model when divisible).

    ``for_decode=False`` (prefill output) skips the head_dim fallback shard:
    prefill computes attention from the same K/V it writes, and a Dh-sharded
    layout back-propagates into every score einsum (measured ~1.1 TiB of
    per-block collectives on gemma prefill_32k).  Decode re-jits with the
    Dh-sharded layout, which is what makes its cache fit HBM."""
    dp = dp_axes(mesh)
    bdim = dp if batch % _axis_size(mesh, dp) == 0 else None
    model_n = mesh.shape["model"]

    def visit(path, leaf):
        names = [str(getattr(k, "key", k)) for k in path]
        name = names[-1]
        shape = leaf.shape
        if name == "pos":
            return NamedSharding(mesh, PS())
        stacked = "cycles" in names
        lead = (None,) if stacked else ()
        core = shape[1:] if stacked else shape
        if name in ("k", "v", "xk", "xv"):
            # (B, S, Hkv, Dh): shard kv heads over model when divisible;
            # otherwise shard head_dim — the score/output contractions then
            # psum small (B,H,Sq) tensors instead of replicating a multi-GiB
            # cache per model shard (llama3.2 decode_32k: 84 -> ~6 GiB/dev)
            if core[2] % model_n == 0:
                return NamedSharding(mesh, PS(*lead, bdim, None, "model",
                                              None))
            dh = "model" if (for_decode and core[3] % model_n == 0) else None
            return NamedSharding(mesh, PS(*lead, bdim, None, None, dh))
        if name == "c" and len(core) == 4:          # mLSTM (B, H, Dh, Dh)
            dh = "model" if core[2] % model_n == 0 else None
            return NamedSharding(mesh, PS(*lead, bdim, None, dh, None))
        if name == "n" and len(core) == 3:          # (B, H, Dh)
            dh = "model" if core[2] % model_n == 0 else None
            return NamedSharding(mesh, PS(*lead, bdim, None, dh))
        if name == "enc_out":
            return NamedSharding(mesh, PS(bdim, None, None))
        if len(core) >= 2 and core[-1] % model_n == 0:
            return NamedSharding(
                mesh, PS(*lead, bdim, *(None,) * (len(core) - 2), "model"))
        return NamedSharding(mesh, PS(*lead, bdim,
                                      *(None,) * (len(core) - 1)))

    return jax.tree_util.tree_map_with_path(visit, cache_abstract)
