"""Roofline-grade analysis of compiled (partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — a scanned
94-layer model looks 94x cheaper than it is.  This module re-derives the
three roofline inputs from the compiled module itself:

  * FLOPs: every ``dot``/``convolution`` op's shape math (2*M*N*K), expanded
    through the call graph with ``known_trip_count`` multipliers on whiles.
  * HBM bytes: operand+output bytes of *fusion-boundary* ops (post-fusion
    HLO makes fusions explicit, so counting their boundaries approximates
    HBM traffic between kernels), same loop expansion.
  * Collective bytes: output bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute(+ -start forms), same
    loop expansion.

All numbers are per-device (the module is the per-partition program).
Elementwise flops are ignored (<2% of matmul flops at these shapes) — noted
in EXPERIMENTS.md §Roofline methodology.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops whose operand/output traffic we count as HBM bytes (fusion boundaries)
_MEM_OPS = {"fusion", "dot", "convolution", "copy", "sort", "scatter",
            "gather", "dynamic-slice", "dynamic-update-slice", "reduce",
            "transpose", "broadcast", "concatenate", "pad", "reshape-mem",
            "select-and-scatter"} | set(_COLLECTIVES) \
    | {c + "-start" for c in _COLLECTIVES}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{}\s/]+?)\s+"
    r"([\w\-]+)\((.*)$")
_CALL_RE = re.compile(r"(?:body|to_apply|calls)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\D*?(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape text."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    # (callee, multiplier, counts_mem): ops fused INTO a kernel don't touch
    # HBM, so fusion-called computations contribute flops but not bytes
    calls: List[Tuple[str, float, bool]] = dataclasses.field(
        default_factory=list)


def _parse_computations(hlo: str) -> Tuple[Dict[str, CompStats], str]:
    comps: Dict[str, CompStats] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    shapes: Dict[str, str] = {}

    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{",
                          stripped)
        if header and not stripped.startswith("//") and cur is None:
            cur = header.group(2)
            comps[cur] = CompStats()
            shapes = {}
            if header.group(1):
                entry = cur
            continue
        if stripped == "}" or stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode, rest = m.groups()
        shapes[name] = shape_str
        stats = comps[cur]

        trip = 1.0
        tm = _TRIP_RE.search(rest)
        if tm:
            trip = float(tm.group(1))
        if opcode == "while":
            for callee in _CALL_RE.findall(rest):
                stats.calls.append((callee, trip, True))
            cm = _COND_RE.search(rest)
            if cm:
                stats.calls.append((cm.group(1), trip, True))
            continue
        if opcode in ("call", "conditional", "map", "custom-call"):
            for callee in _CALL_RE.findall(rest):
                stats.calls.append((callee, 1.0, True))
        elif opcode in ("fusion", "reduce", "reduce-window", "sort",
                        "scatter", "select-and-scatter", "all-reduce",
                        "reduce-scatter"):
            for callee in _CALL_RE.findall(rest):
                stats.calls.append((callee, 1.0, False))

        if opcode in ("dot", "dot_general") or opcode == "convolution":
            out_elems = 1
            for d in _shape_dims(shape_str):
                out_elems *= d
            k = 1
            cm = _CONTRACT_RE.search(rest)
            operands = re.findall(r"%([\w.\-]+)", rest)
            if cm and operands:
                lhs_shape = shapes.get(operands[0], "")
                dims = _shape_dims(lhs_shape)
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        k *= dims[int(idx)]
            elif opcode == "convolution" and operands:
                rhs = _shape_dims(shapes.get(operands[1], ""))
                k = 1
                for d in rhs[:-1]:
                    k *= d
                out_elems = out_elems  # spatial outputs x kernel window
            stats.flops += 2.0 * out_elems * max(k, 1)

        base_op = opcode[:-6] if opcode.endswith("-start") else opcode
        if base_op in _COLLECTIVES:
            b = _shape_bytes(shape_str)
            stats.coll_bytes[base_op] = stats.coll_bytes.get(base_op, 0.0) + b
            stats.coll_bytes["total"] = stats.coll_bytes.get("total", 0.0) + b

        if opcode in _MEM_OPS:
            b = _shape_bytes(shape_str)
            for operand in re.findall(r"%([\w.\-]+)", rest):
                if operand in shapes:
                    b += _shape_bytes(shapes[operand])
            stats.mem_bytes += b
    return comps, entry or ""


def analyze_hlo(hlo: str) -> Dict[str, float]:
    """Loop-expanded per-device {flops, mem_bytes, coll_* bytes}."""
    comps, entry = _parse_computations(hlo)
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def visit(name: str, stack=()) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, {})
        c = comps[name]
        f, m = c.flops, c.mem_bytes
        coll = dict(c.coll_bytes)
        for callee, mult, counts_mem in c.calls:
            cf, cm, cc = visit(callee, stack + (name,))
            f += mult * cf
            if counts_mem:
                m += mult * cm
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + mult * v
        memo[name] = (f, m, coll)
        return memo[name]

    f, m, coll = visit(entry)
    out = {"flops": f, "mem_bytes": m}
    for k, v in coll.items():
        out[f"coll_{k}"] = v
    return out


# ---------------------------------------------------------------------------
# attribution: which source ops dominate each roofline term?
# ---------------------------------------------------------------------------

_METADATA_RE = re.compile(r'op_name="([^"]+)"')


def attribute(hlo: str, top: int = 15) -> Dict[str, List[Tuple[str, float]]]:
    """Per-op-name totals (loop-expanded) for mem / collective / flop bytes.

    Groups by the ``op_name`` metadata (the JAX source path), so the output
    reads like a profile: 'jit(train_step)/.../dot_general' -> bytes.
    """
    comps, entry = _parse_computations(hlo)

    # recompute, but per-instruction with attribution — reuse the parse by
    # walking the text again with a computation->multiplier map
    mult: Dict[str, float] = {}        # through all edges (flops/collectives)
    mult_mem: Dict[str, float] = {}    # not through fusion edges (HBM bytes)

    def spread(name: str, m: float, mm: float, stack=()):
        if name not in comps or name in stack:
            return
        mult[name] = mult.get(name, 0.0) + m
        mult_mem[name] = mult_mem.get(name, 0.0) + mm
        for callee, k, counts_mem in comps[name].calls:
            spread(callee, m * k, mm * k if counts_mem else 0.0,
                   stack + (name,))

    spread(entry, 1.0, 1.0)

    mem: Dict[str, float] = {}
    coll: Dict[str, float] = {}
    flops: Dict[str, float] = {}
    cur: Optional[str] = None
    shapes: Dict[str, str] = {}
    fusion_depth = 0
    for raw in hlo.splitlines():
        stripped = raw.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{",
                          stripped)
        if header and cur is None:
            cur = header.group(2)
            shapes = {}
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None or cur not in mult:
            continue
        m = _INSTR_RE.match(raw)
        if not m:
            continue
        name, shape_str, opcode, rest = m.groups()
        shapes[name] = shape_str
        k = mult[cur]
        meta = _METADATA_RE.search(rest)
        label = meta.group(1) if meta else f"<{opcode}>"

        if opcode in ("dot", "dot_general", "convolution"):
            out_elems = 1
            for d in _shape_dims(shape_str):
                out_elems *= d
            kk = 1
            cm = _CONTRACT_RE.search(rest)
            operands = re.findall(r"%([\w.\-]+)", rest)
            if cm and operands:
                dims = _shape_dims(shapes.get(operands[0], ""))
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        kk *= dims[int(idx)]
            flops[label] = flops.get(label, 0.0) + k * 2.0 * out_elems * kk

        base_op = opcode[:-6] if opcode.endswith("-start") else opcode
        if base_op in _COLLECTIVES:
            coll[label] = coll.get(label, 0.0) + k * _shape_bytes(shape_str)
        if opcode in _MEM_OPS and mult_mem.get(cur, 0.0) > 0:
            b = _shape_bytes(shape_str)
            for operand in re.findall(r"%([\w.\-]+)", rest):
                if operand in shapes:
                    b += _shape_bytes(shapes[operand])
            mem[label] = mem.get(label, 0.0) + mult_mem[cur] * b

    def topk(d):
        return sorted(d.items(), key=lambda kv: -kv[1])[:top]

    return {"mem": topk(mem), "coll": topk(coll), "flops": topk(flops)}
