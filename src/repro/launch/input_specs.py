"""ShapeDtypeStruct stand-ins for every (architecture x shape) cell.

No device allocation — the dry-run lowers and compiles against these.
Conventions per the assignment:
  * train_*   -> train_step(params, opt_state, batch)
  * prefill_* -> prefill(params, batch)  (build a seq_len KV cache)
  * decode_*  -> decode_step(params, cache, token) with a seq_len cache
  * [vlm]: 256 of the seq positions are precomputed patch embeddings
  * [audio]: the encoder consumes 1536 precomputed frame embeddings
    (source side, additional to the decoder's seq_len)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, ShapeConfig
from repro.models.decode import init_cache

F32 = jnp.float32
I32 = jnp.int32


def batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                with_labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    n_tok = s - cfg.frontend_len if cfg.frontend == "patch" else s
    out = {"tokens": jax.ShapeDtypeStruct((b, n_tok), I32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, n_tok), I32)
    if cfg.frontend == "patch":
        out["embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), F32)
    if cfg.frontend == "frames":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), F32)
    return out


def cache_specs(cfg: ArchConfig, shape: ShapeConfig):
    src_len = cfg.frontend_len if cfg.encoder is not None else 0
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           src_len=src_len))


def token_spec(shape: ShapeConfig) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.global_batch,), I32)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """All abstract inputs for this cell, keyed by role."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_labels=False)}
    if shape.kind == "decode":
        return {"cache": cache_specs(cfg, shape),
                "token": token_spec(shape)}
    raise ValueError(shape.kind)


def cell_is_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic decode state (window/recurrent)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention cache at 524288 positions is "
                       "quadratic-cost/unbounded; skipped per spec "
                       "(see DESIGN.md §Arch-applicability)")
    return True, ""
