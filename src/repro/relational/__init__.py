from repro.relational.table import Table, NULL_KEY
from repro.relational.join import (
    sort_merge_join,
    left_outer_join,
    join_count,
    semi_join_mask,
    composite_key,
)
from repro.relational.ops import (
    bag_cancel_mask,
    filter_table,
    project,
    compact,
    dedup,
    concat,
    count_distinct,
    subtract_bag,
    table_digest,
)

__all__ = [
    "Table",
    "NULL_KEY",
    "sort_merge_join",
    "left_outer_join",
    "join_count",
    "semi_join_mask",
    "composite_key",
    "filter_table",
    "project",
    "compact",
    "dedup",
    "concat",
    "count_distinct",
    "subtract_bag",
    "bag_cancel_mask",
    "table_digest",
]
