"""Sort-merge joins with static output shapes.

PostgreSQL (the paper's base system) evaluates every join with hash
build/probe over disk pages.  On TPU, data-dependent pointer chasing is the
wrong primitive; we instead evaluate every join as

    sort(right keys)  ->  two-sided searchsorted(left keys)  ->
    static-capacity pair expansion

which maps onto the VPU (bitonic sorts, vectorized binary search) and keeps
every shape static.  ``N``-to-``N`` joins are handled exactly: each left row
expands into ``hi - lo`` output rows via a cumsum/searchsorted expansion.

Outer-join semantics follow Theorem 4.3 of the paper: a left row with no
match emits exactly one output row whose right side is *null*, signalled by
an indicator column (never by sentinel data values).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational.table import NULL_KEY, Table

NULL_KEY64 = np.int32(2**31 - 1)


def composite_key(table: Table, cols: Sequence[str]) -> jax.Array:
    """Null-aware int32 sort key for a single key column.

    Invalid rows map to ``NULL_KEY64`` (int32 max) so they sort last and never
    match a valid key (valid ids must be < 2**31-1).  Joins with multiple
    equality conditions sort/search on the *first* condition and apply the
    remaining conditions as exact post-filters — single-column equijoins are
    the common case in graph-model workloads, and this keeps all keys in
    int32 (JAX's default-x64-off world) without lossy packing.
    """
    if len(cols) != 1:
        raise ValueError(f"composite_key takes exactly 1 column, got {cols}")
    k = table[cols[0]].astype(jnp.int32)
    return jnp.where(table.valid, k, NULL_KEY64)


def _expansion(counts: jax.Array, capacity: int):
    """Map output slots [0, capacity) to (source row, within-row rank).

    Given per-left-row output counts, returns (row, rank, valid) for each
    output slot.  Output is prefix-compacted: slot j is valid iff j < total.
    """
    cum = jnp.cumsum(counts)                     # inclusive
    total = cum[-1] if counts.shape[0] else jnp.int32(0)
    slots = jnp.arange(capacity, dtype=counts.dtype)
    row = jnp.searchsorted(cum, slots, side="right")
    row = jnp.clip(row, 0, counts.shape[0] - 1)
    start = cum[row] - counts[row]               # exclusive offset of row
    rank = slots - start
    valid = slots < total
    return row, rank, valid, total


@functools.partial(jax.jit, static_argnames=("on_left", "on_right"))
def join_count(
    left: Table,
    right: Table,
    on_left: Tuple[str, ...],
    on_right: Tuple[str, ...],
) -> jax.Array:
    """Exact inner-join output cardinality (first <=2 key columns)."""
    lk = composite_key(left, on_left)
    rk = composite_key(right, on_right)
    rk_sorted = jnp.sort(rk)
    lo = jnp.searchsorted(rk_sorted, lk, side="left")
    hi = jnp.searchsorted(rk_sorted, lk, side="right")
    counts = jnp.where(left.valid & (lk != NULL_KEY64), hi - lo, 0)
    return jnp.sum(counts)


@functools.partial(
    jax.jit,
    static_argnames=("on_left", "on_right", "how", "capacity", "indicator"),
)
def _join_impl(
    left: Table,
    right: Table,
    on_left: Tuple[str, ...],
    on_right: Tuple[str, ...],
    how: str,
    capacity: int,
    indicator: Optional[str],
) -> Table:
    lk = composite_key(left, on_left)
    rk = composite_key(right, on_right)
    order = jnp.argsort(rk)
    rk_sorted = rk[order]
    lo = jnp.searchsorted(rk_sorted, lk, side="left")
    hi = jnp.searchsorted(rk_sorted, lk, side="right")
    match_counts = jnp.where(left.valid & (lk != NULL_KEY64), hi - lo, 0)
    if how == "inner":
        counts = match_counts
    elif how == "left_outer":
        counts = jnp.where(left.valid, jnp.maximum(match_counts, 1), 0)
    else:
        raise ValueError(f"unknown join kind {how!r}")

    row, rank, valid, _ = _expansion(counts, capacity)
    matched = rank < match_counts[row]
    rpos = jnp.clip(lo[row] + rank, 0, max(right.capacity - 1, 0))
    ridx = order[rpos]

    cols = {}
    for name, col in left.columns.items():
        cols[name] = col[row]
    for name, col in right.columns.items():
        if name in cols:
            raise ValueError(f"column collision on {name!r}; prefix aliases first")
        cols[name] = col[ridx]
    out_valid = valid
    if how == "left_outer":
        ind = matched & valid
        if indicator is not None:
            cols[indicator] = ind
    else:
        out_valid = valid & matched  # matched is all-True for valid inner slots
    return Table(columns=cols, valid=out_valid)


def _round_capacity(n: int) -> int:
    return max(8, int(1 << int(np.ceil(np.log2(max(n, 1) + 1)))))


def sort_merge_join(
    left: Table,
    right: Table,
    on: Sequence[Tuple[str, str]],
    how: str = "inner",
    capacity: Optional[int] = None,
    indicator: Optional[str] = None,
) -> Table:
    """Join two tables on equality conditions ``[(lcol, rcol), ...]``.

    The first two conditions form the sort key; any further conditions are
    applied as an exact post-filter.  If ``capacity`` is None the exact
    cardinality is computed first (two-phase execution, the eager ETL path);
    pass a static ``capacity`` for fully-jitted / distributed execution.
    """
    on = list(on)
    key_on, rest = on[:1], on[1:]
    on_left = tuple(l for l, _ in key_on)
    on_right = tuple(r for _, r in key_on)
    if capacity is None:
        n = int(join_count(left, right, on_left, on_right))
        if how == "left_outer":
            n += int(left.num_rows())  # upper bound incl. unmatched rows
        capacity = _round_capacity(n)
    out = _join_impl(left, right, on_left, on_right, how, capacity, indicator)
    for lcol, rcol in rest:
        keep = out[lcol] == out[rcol]
        if how == "left_outer" and indicator is not None:
            # extra predicates only constrain *matched* rows
            out = out.with_columns(**{indicator: out[indicator] & keep})
        else:
            out = out.mask(keep)
    return out


def left_outer_join(
    left: Table,
    right: Table,
    on: Sequence[Tuple[str, str]],
    indicator: str,
    capacity: Optional[int] = None,
) -> Table:
    """Exact left-outer join for any number of equality conditions.

    With one condition this is :func:`sort_merge_join`'s native outer path.
    With several, a first-key inner expansion + post-filter can leave an
    unmatched left row represented by *multiple* indicator=False rows, which
    would corrupt bag semantics of later chained outer joins (Thm 4.3 needs
    exactly one null row per unmatched left row).  Here we instead take the
    exact inner join and append exactly one null row per unmatched left row.
    """
    if len(on) == 1:
        return sort_merge_join(
            left, right, on, how="left_outer",
            capacity=capacity, indicator=indicator,
        )
    rowid = "__rowid__"
    lt = left.with_columns(**{rowid: jnp.arange(left.capacity, dtype=jnp.int32)})
    inner = sort_merge_join(lt, right, on, how="inner", capacity=capacity)
    # which left rows matched at least once?
    hits = jnp.zeros((left.capacity,), dtype=jnp.int32)
    hits = hits.at[inner[rowid]].add(inner.valid.astype(jnp.int32))
    unmatched = left.valid & (hits == 0)

    matched_part = inner.with_columns(
        **{indicator: inner.valid}
    )
    null_right = {
        name: jnp.zeros((left.capacity,), dtype=col.dtype)
        for name, col in right.columns.items()
    }
    unmatched_part = Table(
        columns={
            **left.columns,
            rowid: jnp.arange(left.capacity, dtype=jnp.int32),
            **null_right,
            indicator: jnp.zeros((left.capacity,), dtype=bool),
        },
        valid=unmatched,
    )
    names = matched_part.column_names()
    cols = {
        n: jnp.concatenate([matched_part[n], unmatched_part[n]]) for n in names
    }
    out = Table(
        columns=cols,
        valid=jnp.concatenate([matched_part.valid, unmatched_part.valid]),
    )
    return Table(
        columns={k: v for k, v in out.columns.items() if k != rowid},
        valid=out.valid,
    )


def semi_join_mask(
    left: Table, right: Table, on: Sequence[Tuple[str, str]]
) -> jax.Array:
    """Boolean mask over left rows with >=1 match in right (for pruning).

    Approximate (never false-negative) when more than one condition is given:
    only the first condition is checked.
    """
    on = list(on)[:1]
    lk = composite_key(left, tuple(l for l, _ in on))
    rk = composite_key(right, tuple(r for _, r in on))
    rk_sorted = jnp.sort(rk)
    lo = jnp.searchsorted(rk_sorted, lk, side="left")
    hi = jnp.searchsorted(rk_sorted, lk, side="right")
    return left.valid & (lk != NULL_KEY64) & (hi > lo)
