"""Sort-merge joins with static output shapes.

PostgreSQL (the paper's base system) evaluates every join with hash
build/probe over disk pages.  On TPU, data-dependent pointer chasing is the
wrong primitive; we instead evaluate every join as

    sort(right keys)  ->  two-sided searchsorted(left keys)  ->
    static-capacity pair expansion

which maps onto the VPU (bitonic sorts, vectorized binary search) and keeps
every shape static.  ``N``-to-``N`` joins are handled exactly: each left row
expands into ``hi - lo`` output rows via a cumsum/searchsorted expansion.

Outer-join semantics follow Theorem 4.3 of the paper: a left row with no
match emits exactly one output row whose right side is *null*, signalled by
an indicator column (never by sentinel data values).
"""
from __future__ import annotations

import functools
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational.table import NULL_KEY, Table

NULL_KEY64 = np.int32(2**31 - 1)

# Host-time spent in the eager two-phase path's count/sync step; read by
# benchmarks to attribute the cold-path "count" phase (the per-join host
# round-trip the compiled pipeline eliminates).
_TWO_PHASE_STATS = {"count_calls": 0, "count_s": 0.0}


def two_phase_stats() -> dict:
    """Snapshot of {count_calls, count_s} for the eager count→expand path."""
    return dict(_TWO_PHASE_STATS)


def reset_two_phase_stats() -> None:
    _TWO_PHASE_STATS["count_calls"] = 0
    _TWO_PHASE_STATS["count_s"] = 0.0


def composite_key(table: Table, cols: Sequence[str]) -> jax.Array:
    """Null-aware int32 sort key for a single key column.

    Invalid rows map to ``NULL_KEY64`` (int32 max) so they sort last and never
    match a valid key (valid ids must be < 2**31-1).  Joins with multiple
    equality conditions sort/search on the *first* condition and apply the
    remaining conditions as exact post-filters — single-column equijoins are
    the common case in graph-model workloads, and this keeps all keys in
    int32 (JAX's default-x64-off world) without lossy packing.
    """
    if len(cols) != 1:
        raise ValueError(f"composite_key takes exactly 1 column, got {cols}")
    k = table[cols[0]].astype(jnp.int32)
    return jnp.where(table.valid, k, NULL_KEY64)


def _expansion(counts: jax.Array, capacity: int):
    """Map output slots [0, capacity) to (source row, within-row rank).

    Given per-left-row output counts, returns (row, rank, valid) for each
    output slot.  Output is prefix-compacted: slot j is valid iff j < total.
    """
    cum = jnp.cumsum(counts)                     # inclusive
    total = cum[-1] if counts.shape[0] else jnp.int32(0)
    slots = jnp.arange(capacity, dtype=counts.dtype)
    # row[j] = #{i : cum[i] <= j} (== searchsorted(cum, slots, "right"), but
    # a scatter+scan compiles and runs cheaper than a bisection loop)
    mark = jnp.zeros((capacity + 1,), counts.dtype)
    mark = mark.at[jnp.clip(cum, 0, capacity)].add(1)
    row = jnp.cumsum(mark)[:capacity]
    row = jnp.clip(row, 0, counts.shape[0] - 1)
    start = cum[row] - counts[row]               # exclusive offset of row
    rank = slots - start
    valid = slots < total
    return row, rank, valid, total


@functools.partial(jax.jit, static_argnames=("on_left", "on_right"))
def join_count(
    left: Table,
    right: Table,
    on_left: Tuple[str, ...],
    on_right: Tuple[str, ...],
) -> jax.Array:
    """Exact inner-join output cardinality on the single sort-key column.

    Only the first equality condition is counted — the same contract as
    :func:`composite_key` / :func:`sort_merge_join`, where exactly one
    column forms the sort key and any further conditions are exact
    post-filters.  This is the upper bound the two-phase eager path sizes
    its output capacity with (post-filters only shrink the result).
    """
    lk = composite_key(left, on_left)
    rk = composite_key(right, on_right)
    rk_sorted = jnp.sort(rk)
    lo = jnp.searchsorted(rk_sorted, lk, side="left")
    hi = jnp.searchsorted(rk_sorted, lk, side="right")
    counts = jnp.where(left.valid & (lk != NULL_KEY64), hi - lo, 0)
    return jnp.sum(counts)


def _probe_ranges(rk_sorted: jax.Array, lk: jax.Array, use_kernel: bool):
    """(lo, hi) match ranges; Pallas ``sorted_probe`` or jnp bisection.

    The jnp path runs a single bisection over ``[lk, lk + 1]``: keys are
    int32, so ``side="right"`` of ``k`` equals ``side="left"`` of ``k + 1``.
    The only key that wraps is ``NULL_KEY64`` (int32 max), whose rows are
    masked out of the match counts anyway.
    """
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.sorted_probe(rk_sorted, lk)
    n = lk.shape[0]
    pos = jnp.searchsorted(rk_sorted, jnp.concatenate([lk, lk + 1]),
                           side="left")
    return pos[:n], pos[n:]


def _join_core(
    left: Table,
    right: Table,
    lk: jax.Array,
    rk: jax.Array,
    how: str,
    capacity: int,
    indicator: Optional[str],
    use_kernel: bool,
) -> Tuple[Table, jax.Array]:
    """Static-capacity pair expansion; returns (table, required_rows).

    ``required_rows`` is the exact (traced, pre-truncation) number of
    output slots the join needed; the result is silently prefix-truncated
    when it exceeds ``capacity``, which callers detect by comparing the two.
    """
    order = jnp.argsort(rk)
    rk_sorted = rk[order]
    lo, hi = _probe_ranges(rk_sorted, lk, use_kernel)
    match_counts = jnp.where(left.valid & (lk != NULL_KEY64), hi - lo, 0)
    if how == "inner":
        counts = match_counts
    elif how == "left_outer":
        counts = jnp.where(left.valid, jnp.maximum(match_counts, 1), 0)
    else:
        raise ValueError(f"unknown join kind {how!r}")

    row, rank, valid, total = _expansion(counts, capacity)
    matched = rank < match_counts[row]
    rpos = jnp.clip(lo[row] + rank, 0, max(right.capacity - 1, 0))
    ridx = order[rpos]

    cols = {}
    for name, col in left.columns.items():
        cols[name] = col[row]
    for name, col in right.columns.items():
        if name in cols:
            raise ValueError(f"column collision on {name!r}; prefix aliases first")
        cols[name] = col[ridx]
    out_valid = valid
    if how == "left_outer":
        ind = matched & valid
        if indicator is not None:
            cols[indicator] = ind
    else:
        out_valid = valid & matched  # matched is all-True for valid inner slots
    return Table(columns=cols, valid=out_valid), total


def join_with_capacity(
    left: Table,
    right: Table,
    on: Sequence[Tuple[str, str]],
    how: str = "inner",
    *,
    capacity: int,
    indicator: Optional[str] = None,
    use_kernel: bool = False,
    bloom_bits: int = 0,
) -> Tuple[Table, jax.Array]:
    """Fully-traced join at a static capacity; returns (table, required).

    The building block of the compiled pipeline executor
    (:mod:`repro.core.pipeline`): no host syncs, no data-dependent shapes.
    ``required`` is the traced exact number of output slots the first-key
    expansion needed; if it exceeds ``capacity`` the output was truncated
    and the caller must re-execute at a larger capacity (the pipeline's
    overflow-retry).  ``use_kernel`` routes the probe phase through the
    Pallas ``sorted_probe`` kernel; ``bloom_bits > 0`` additionally prunes
    probe rows through a Bloom-filter semi-join *before* the capacity
    expansion.  Bloom filters have no false negatives, so pruning is exact
    for inner joins and turns outer-join prunees into (correct) unmatched
    null rows.
    """
    on = list(on)
    key_on, rest = on[:1], on[1:]
    on_left = tuple(l for l, _ in key_on)
    on_right = tuple(r for _, r in key_on)
    lk = composite_key(left, on_left)
    rk = composite_key(right, on_right)
    if bloom_bits:
        from repro.kernels import ops as kops

        bits = kops.bloom_build(rk, right.valid & (rk != NULL_KEY64),
                                bloom_bits)
        lk = jnp.where(kops.bloom_probe(bits, lk), lk, NULL_KEY64)
    out, total = _join_core(left, right, lk, rk, how, capacity, indicator,
                            use_kernel)
    for lcol, rcol in rest:
        keep = out[lcol] == out[rcol]
        if how == "left_outer" and indicator is not None:
            # extra predicates only constrain *matched* rows
            out = out.with_columns(**{indicator: out[indicator] & keep})
        else:
            out = out.mask(keep)
    return out, total


def left_outer_with_capacity(
    left: Table,
    right: Table,
    on: Sequence[Tuple[str, str]],
    indicator: str,
    capacity: int,
    use_kernel: bool = False,
    bloom_bits: int = 0,
) -> Tuple[Table, jax.Array]:
    """Traced exact left-outer join at static capacity; (table, required).

    Mirrors :func:`left_outer_join`: with one condition this is the native
    outer path at ``capacity``; with several, the exact first-key inner
    expansion (at ``capacity``) plus exactly one null row appended per
    unmatched left row (output capacity ``capacity + left.capacity``, which
    is static and can never overflow — ``required`` tracks the inner part).
    """
    on = list(on)
    if len(on) == 1:
        return join_with_capacity(
            left, right, on, how="left_outer", capacity=capacity,
            indicator=indicator, use_kernel=use_kernel,
            bloom_bits=bloom_bits)
    rowid = "__rowid__"
    lt = left.with_columns(**{rowid: jnp.arange(left.capacity,
                                                dtype=jnp.int32)})
    inner, total = join_with_capacity(
        lt, right, on, how="inner", capacity=capacity,
        use_kernel=use_kernel, bloom_bits=bloom_bits)
    hits = jnp.zeros((left.capacity,), dtype=jnp.int32)
    hits = hits.at[inner[rowid]].add(inner.valid.astype(jnp.int32))
    unmatched = left.valid & (hits == 0)

    matched_part = inner.with_columns(**{indicator: inner.valid})
    null_right = {
        name: jnp.zeros((left.capacity,), dtype=col.dtype)
        for name, col in right.columns.items()
    }
    unmatched_part = Table(
        columns={
            **left.columns,
            rowid: jnp.arange(left.capacity, dtype=jnp.int32),
            **null_right,
            indicator: jnp.zeros((left.capacity,), dtype=bool),
        },
        valid=unmatched,
    )
    names = matched_part.column_names()
    cols = {
        n: jnp.concatenate([matched_part[n], unmatched_part[n]])
        for n in names
    }
    valid = jnp.concatenate([matched_part.valid, unmatched_part.valid])
    return Table(
        columns={k: v for k, v in cols.items() if k != rowid}, valid=valid
    ), total


@functools.partial(
    jax.jit, static_argnames=("on", "how", "capacity", "indicator"),
)
def _join_jit(
    left: Table,
    right: Table,
    on: Tuple[Tuple[str, str], ...],
    how: str,
    capacity: int,
    indicator: Optional[str],
) -> Table:
    return join_with_capacity(
        left, right, on, how, capacity=capacity, indicator=indicator)[0]


def round_capacity(n: int) -> int:
    """Smallest pow-2 capacity strictly above ``n`` (min 8).

    The one capacity-bucketing rule shared by the eager two-phase path,
    the compiled pipeline, and incremental delta tables — bucketing keeps
    jitted shapes stable across requests (and across refreshes at similar
    churn), which is what makes executable caches hit.
    """
    return max(8, int(1 << int(np.ceil(np.log2(max(n, 1) + 1)))))


_round_capacity = round_capacity  # historical private name, kept for callers


def sort_merge_join(
    left: Table,
    right: Table,
    on: Sequence[Tuple[str, str]],
    how: str = "inner",
    capacity: Optional[int] = None,
    indicator: Optional[str] = None,
) -> Table:
    """Join two tables on equality conditions ``[(lcol, rcol), ...]``.

    The first condition forms the (single-column) sort key; any further
    conditions are applied as an exact post-filter — the contract
    :func:`composite_key` enforces.  If ``capacity`` is None the exact
    cardinality is computed first (two-phase execution, the eager ETL path,
    one host round-trip per join); pass a static ``capacity`` for
    fully-jitted / distributed execution, or use the compiled pipeline
    (:mod:`repro.core.pipeline`) which pre-sizes capacities from the cost
    model and retries on overflow.
    """
    on = tuple((l, r) for l, r in on)
    if capacity is None:
        t0 = time.perf_counter()
        on_left = (on[0][0],)
        on_right = (on[0][1],)
        n = int(join_count(left, right, on_left, on_right))
        if how == "left_outer":
            n += int(left.num_rows())  # upper bound incl. unmatched rows
        capacity = _round_capacity(n)
        _TWO_PHASE_STATS["count_calls"] += 1
        _TWO_PHASE_STATS["count_s"] += time.perf_counter() - t0
    return _join_jit(left, right, on, how, capacity, indicator)


@functools.partial(
    jax.jit, static_argnames=("on", "indicator", "capacity"),
)
def _outer_jit(
    left: Table,
    right: Table,
    on: Tuple[Tuple[str, str], ...],
    indicator: str,
    capacity: int,
) -> Table:
    return left_outer_with_capacity(left, right, on, indicator, capacity)[0]


def left_outer_join(
    left: Table,
    right: Table,
    on: Sequence[Tuple[str, str]],
    indicator: str,
    capacity: Optional[int] = None,
) -> Table:
    """Exact left-outer join for any number of equality conditions.

    The eager two-phase wrapper over :func:`left_outer_with_capacity` (one
    implementation of the Thm 4.3 invariant — exactly one null row per
    unmatched left row): ``capacity=None`` counts the first-key expansion
    first, exactly like :func:`sort_merge_join`.  With several conditions
    ``capacity`` sizes the inner expansion only; the appended unmatched
    rows are bounded by ``left.capacity`` statically.
    """
    on = tuple((l, r) for l, r in on)
    if capacity is None:
        t0 = time.perf_counter()
        n = int(join_count(left, right, (on[0][0],), (on[0][1],)))
        if len(on) == 1:
            n += int(left.num_rows())  # native outer path holds null rows too
        capacity = _round_capacity(n)
        _TWO_PHASE_STATS["count_calls"] += 1
        _TWO_PHASE_STATS["count_s"] += time.perf_counter() - t0
    return _outer_jit(left, right, on, indicator, capacity)


def semi_join_mask(
    left: Table, right: Table, on: Sequence[Tuple[str, str]]
) -> jax.Array:
    """Boolean mask over left rows with >=1 match in right (for pruning).

    Approximate (never false-negative) when more than one condition is given:
    only the first condition is checked.
    """
    on = list(on)[:1]
    lk = composite_key(left, tuple(l for l, _ in on))
    rk = composite_key(right, tuple(r for _, r in on))
    rk_sorted = jnp.sort(rk)
    lo = jnp.searchsorted(rk_sorted, lk, side="left")
    hi = jnp.searchsorted(rk_sorted, lk, side="right")
    return left.valid & (lk != NULL_KEY64) & (hi > lo)
