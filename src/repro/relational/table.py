"""Columnar, static-shape relational tables for JAX.

A :class:`Table` is the TPU-native replacement for a row-store relation:
every column is a dense 1-D array of identical static length (``capacity``),
and a boolean ``valid`` mask carries the dynamic cardinality.  All relational
operators in :mod:`repro.relational` preserve this invariant, which is what
makes whole extraction plans jit-able and shardable with ``pjit``/``shard_map``.

Conventions
-----------
* Key columns are ``int32`` (non-negative ids).  ``float32`` measure columns
  are allowed but never joined on.
* Invalid rows may hold arbitrary garbage; operators must mask through
  ``valid`` and never rely on invalid slots being zeroed.
* Join outputs are *prefix-compacted*: valid rows occupy slots ``[0, n)``.
  Filter outputs are not; use :func:`repro.relational.ops.compact` if a
  prefix layout is required (e.g. before an ``all_to_all`` repartition).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel used for invalid / null int32 keys.  Valid ids must be < NULL_KEY.
NULL_KEY = np.int32(2**31 - 1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Table:
    """An immutable columnar relation with a validity mask.

    Attributes:
      columns: mapping column-name -> 1-D array, all of length ``capacity``.
      valid:   bool array of length ``capacity``; True where the row is live.
    """

    columns: Dict[str, jax.Array]
    valid: jax.Array

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.valid,)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        *cols, valid = children
        return cls(columns=dict(zip(names, cols)), valid=valid)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_arrays(cls, capacity: int | None = None, **columns) -> "Table":
        """Build a table from equal-length arrays, padding to ``capacity``."""
        cols = {k: jnp.asarray(v) for k, v in columns.items()}
        if not cols:
            raise ValueError("Table needs at least one column")
        n = len(next(iter(cols.values())))
        for k, v in cols.items():
            if v.ndim != 1 or len(v) != n:
                raise ValueError(f"column {k!r} has shape {v.shape}, want ({n},)")
        cap = n if capacity is None else capacity
        if cap < n:
            raise ValueError(f"capacity {cap} < data length {n}")
        valid = jnp.arange(cap) < n
        padded = {}
        for k, v in cols.items():
            pad = jnp.zeros((cap - n,), dtype=v.dtype)
            padded[k] = jnp.concatenate([v, pad]) if cap > n else v
        return cls(columns=padded, valid=valid)

    @classmethod
    def empty_like(cls, other: "Table", capacity: int) -> "Table":
        cols = {
            k: jnp.zeros((capacity,), dtype=v.dtype)
            for k, v in other.columns.items()
        }
        return cls(columns=cols, valid=jnp.zeros((capacity,), dtype=bool))

    # -- accessors ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def num_rows(self) -> jax.Array:
        """Traced count of live rows."""
        return jnp.sum(self.valid.astype(jnp.int32))

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def column_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self.columns))

    # -- basic transforms (shape-preserving) ---------------------------------
    def with_columns(self, **extra) -> "Table":
        cols = dict(self.columns)
        for k, v in extra.items():
            v = jnp.asarray(v)
            if v.shape != (self.capacity,):
                raise ValueError(f"column {k!r} shape {v.shape} != ({self.capacity},)")
            cols[k] = v
        return Table(columns=cols, valid=self.valid)

    def select(self, names) -> "Table":
        return Table(
            columns={n: self.columns[n] for n in names}, valid=self.valid
        )

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        cols = {mapping.get(k, k): v for k, v in self.columns.items()}
        if len(cols) != len(self.columns):
            raise ValueError(f"rename collision: {mapping}")
        return Table(columns=cols, valid=self.valid)

    def prefix(self, alias: str) -> "Table":
        """Namespace every column as ``<alias>.<col>`` (query-alias scoping)."""
        return self.rename({k: f"{alias}.{k}" for k in self.columns})

    def mask(self, keep: jax.Array) -> "Table":
        return Table(columns=self.columns, valid=self.valid & keep)

    # -- host-side materialization (tests / debugging) -----------------------
    def to_numpy(self) -> Dict[str, np.ndarray]:
        """Return compacted host arrays containing only valid rows."""
        valid = np.asarray(self.valid)
        return {k: np.asarray(v)[valid] for k, v in self.columns.items()}

    def to_rowset(self, names=None) -> set:
        """Set of row tuples over ``names`` (default all columns), valid only.

        Multisets are represented by appending a per-duplicate rank so tests
        can compare join results exactly (bag semantics).
        """
        names = list(names) if names is not None else list(self.column_names())
        data = self.to_numpy()
        rows = list(zip(*(data[n].tolist() for n in names))) if names else []
        seen: Dict[tuple, int] = {}
        out = set()
        for r in rows:
            k = seen.get(r, 0)
            seen[r] = k + 1
            out.add(r + (k,))
        return out
