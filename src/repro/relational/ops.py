"""Non-join relational operators: filter, project, dedup, compact, concat."""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational.join import composite_key
from repro.relational.table import Table

_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


def filter_table(table: Table, col: str, op: str, value) -> Table:
    """sigma_{col op value}(table); mask-only, shape preserved."""
    return table.mask(_OPS[op](table[col], value))


def project(table: Table, names: Sequence[str]) -> Table:
    return table.select(list(names))


@functools.partial(jax.jit, static_argnames=("capacity",))
def compact(table: Table, capacity: Optional[int] = None) -> Table:
    """Stable-move valid rows to the front (prefix layout).

    Needed before fixed-capacity shard exchange (all_to_all) and before
    slicing a table down to a smaller capacity.
    """
    cap = capacity or table.capacity
    # stable argsort of (not valid) keeps relative order of valid rows
    order = jnp.argsort(~table.valid, stable=True)
    order = order[:cap]
    cols = {k: v[order] for k, v in table.columns.items()}
    valid = table.valid[order]
    return Table(columns=cols, valid=valid)


def dedup(table: Table, keys: Sequence[str]) -> Table:
    """Keep one valid row per distinct key tuple (any number of key columns).

    Lexicographic sort (invalid rows last) + neighbour comparison; rows come
    back key-sorted with duplicates masked out.  No 64-bit packing needed.
    """
    keys = list(keys)
    # lexsort: last key is the primary -> order (minor..major)
    sort_keys = [table[k] for k in reversed(keys)] + [~table.valid]
    order = jnp.lexsort(tuple(sort_keys))
    sorted_valid = table.valid[order]
    same = jnp.ones(table.capacity, dtype=bool)
    for k in keys:
        col = table[k][order]
        eq = jnp.concatenate([jnp.array([False]), col[1:] == col[:-1]])
        same = same & eq
    prev_valid = jnp.concatenate([jnp.array([False]), sorted_valid[:-1]])
    first = ~(same & prev_valid)
    cols = {name: col[order] for name, col in table.columns.items()}
    return Table(columns=cols, valid=sorted_valid & first)


def concat(tables: Sequence[Table]) -> Table:
    names = tables[0].column_names()
    for t in tables[1:]:
        if t.column_names() != names:
            raise ValueError("concat requires identical schemas")
    cols = {
        n: jnp.concatenate([t[n] for t in tables]) for n in names
    }
    valid = jnp.concatenate([t.valid for t in tables])
    return Table(columns=cols, valid=valid)


def bag_cancel_mask(
    main_cols: Sequence[np.ndarray],
    main_valid: np.ndarray,
    minus_cols: Sequence[np.ndarray],
    minus_valid: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Keep-mask over main rows after bag-cancelling ``minus`` rows.

    Multiset difference on the key tuple formed by the given columns: a
    minus row with multiplicity ``m`` invalidates exactly ``m`` matching
    valid main rows (the first ``m`` in a canonical sort — which ones is
    immaterial under bag semantics).  Host-side numpy: one lexsort of the
    combined rows; no compile, no device sync.  Invalid main rows stay
    invalid; minus rows with no match cancel nothing.
    """
    main_cols = [np.asarray(c) for c in main_cols]
    minus_cols = [np.asarray(c) for c in minus_cols]
    main_valid = np.asarray(main_valid, dtype=bool)
    n = main_valid.shape[0]
    if minus_valid is None:
        minus_valid = np.ones(minus_cols[0].shape, dtype=bool) \
            if minus_cols else np.zeros((0,), dtype=bool)
    minus_valid = np.asarray(minus_valid, dtype=bool)
    m = minus_valid.shape[0]
    if m == 0 or not minus_valid.any():
        return main_valid.copy()

    # Prefilter: only main rows sharing the first key value with some minus
    # row can cancel.  Minus sides are tiny relative to maintained tables
    # (that is the point of incremental maintenance), so this turns an
    # O(n log n) lexsort over the whole table into one binary search plus a
    # lexsort over the few candidate rows.
    uniq = np.unique(minus_cols[0][minus_valid])
    pos = np.searchsorted(uniq, main_cols[0])
    pos_c = np.minimum(pos, len(uniq) - 1)
    cand = main_valid & (uniq[pos_c] == main_cols[0])
    if not cand.any():
        return main_valid.copy()
    if cand.sum() < n:
        idx = np.flatnonzero(cand)
        sub_keep = bag_cancel_mask(
            [c[idx] for c in main_cols], np.ones(len(idx), dtype=bool),
            minus_cols, minus_valid)
        keep = main_valid.copy()
        keep[idx] = sub_keep
        return keep

    cols = [np.concatenate([a, b]) for a, b in zip(main_cols, minus_cols)]
    is_main = np.concatenate(
        [np.ones(n, dtype=np.int8), np.zeros(m, dtype=np.int8)])
    valid = np.concatenate([main_valid, minus_valid])
    # priority: valid rows first, then key columns, then minus before main
    order = np.lexsort((is_main,) + tuple(reversed(cols)) + (~valid,))
    idx = np.arange(n + m)
    s_main = is_main[order].astype(bool)
    s_valid = valid[order]
    same = np.ones(n + m, dtype=bool)
    for c in cols:
        sc = c[order]
        same[1:] &= sc[1:] == sc[:-1]
    same[0] = False
    new_group = ~same
    group_start = np.maximum.accumulate(np.where(new_group, idx, -1))
    prev_main = np.concatenate([[False], s_main[:-1]])
    first_main = s_main & (new_group | ~prev_main)
    fm_pos = np.maximum.accumulate(np.where(first_main, idx, -1))
    # main row at sorted pos p: its group holds (fm - start) minus rows,
    # all sorted ahead of the mains; cancel the first that many mains
    num_minus = fm_pos - group_start
    cancel = s_main & s_valid & ((idx - fm_pos) < num_minus)
    keep_sorted = ~cancel
    keep = np.empty(n + m, dtype=bool)
    keep[order] = keep_sorted
    return main_valid & keep[:n]


def subtract_bag(table: Table, minus: Table,
                 keys: Optional[Sequence[str]] = None) -> Table:
    """Bag difference ``table ∖ minus`` over ``keys`` (default: all of
    ``minus``'s columns).  Each valid minus row invalidates one matching
    valid row; shape is preserved (mask-only, like :func:`filter_table`).
    """
    if keys is None:
        keys = minus.column_names()
    keep = bag_cancel_mask(
        [np.asarray(table[k]) for k in keys],
        np.asarray(table.valid),
        [np.asarray(minus[k]) for k in keys],
        np.asarray(minus.valid),
    )
    return table.mask(jnp.asarray(keep))


def count_distinct(table: Table, col: str) -> int:
    """Host-side distinct count of a key column (ANALYZE-style statistic)."""
    vals = np.asarray(table[col])[np.asarray(table.valid)]
    return int(np.unique(vals).size)


def table_digest(table: Table) -> str:
    """Content address of the *valid* rows (column names + values).

    Rows are canonicalized by a lexicographic sort first, so the digest is
    a *bag* address: padding, capacity, and row order — which vary with the
    plan that produced the table — never change it.  Used to
    content-address derived artifacts (e.g. the engine's CSR cache, where
    ``extgraph`` and ``ringo`` runs of one model must collide).
    """
    import hashlib

    h = hashlib.sha1()
    data = table.to_numpy()
    names = sorted(data)
    n = len(data[names[0]]) if names else 0
    if n:
        order = np.lexsort(tuple(data[k] for k in reversed(names)))
    else:
        order = np.arange(0)
    for name in names:
        h.update(name.encode())
        h.update(np.ascontiguousarray(data[name][order]).tobytes())
    return h.hexdigest()[:16]
