"""Non-join relational operators: filter, project, dedup, compact, concat."""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relational.join import composite_key
from repro.relational.table import Table

_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


def filter_table(table: Table, col: str, op: str, value) -> Table:
    """sigma_{col op value}(table); mask-only, shape preserved."""
    return table.mask(_OPS[op](table[col], value))


def project(table: Table, names: Sequence[str]) -> Table:
    return table.select(list(names))


@functools.partial(jax.jit, static_argnames=("capacity",))
def compact(table: Table, capacity: Optional[int] = None) -> Table:
    """Stable-move valid rows to the front (prefix layout).

    Needed before fixed-capacity shard exchange (all_to_all) and before
    slicing a table down to a smaller capacity.
    """
    cap = capacity or table.capacity
    # stable argsort of (not valid) keeps relative order of valid rows
    order = jnp.argsort(~table.valid, stable=True)
    order = order[:cap]
    cols = {k: v[order] for k, v in table.columns.items()}
    valid = table.valid[order]
    return Table(columns=cols, valid=valid)


def dedup(table: Table, keys: Sequence[str]) -> Table:
    """Keep one valid row per distinct key tuple (any number of key columns).

    Lexicographic sort (invalid rows last) + neighbour comparison; rows come
    back key-sorted with duplicates masked out.  No 64-bit packing needed.
    """
    keys = list(keys)
    # lexsort: last key is the primary -> order (minor..major)
    sort_keys = [table[k] for k in reversed(keys)] + [~table.valid]
    order = jnp.lexsort(tuple(sort_keys))
    sorted_valid = table.valid[order]
    same = jnp.ones(table.capacity, dtype=bool)
    for k in keys:
        col = table[k][order]
        eq = jnp.concatenate([jnp.array([False]), col[1:] == col[:-1]])
        same = same & eq
    prev_valid = jnp.concatenate([jnp.array([False]), sorted_valid[:-1]])
    first = ~(same & prev_valid)
    cols = {name: col[order] for name, col in table.columns.items()}
    return Table(columns=cols, valid=sorted_valid & first)


def concat(tables: Sequence[Table]) -> Table:
    names = tables[0].column_names()
    for t in tables[1:]:
        if t.column_names() != names:
            raise ValueError("concat requires identical schemas")
    cols = {
        n: jnp.concatenate([t[n] for t in tables]) for n in names
    }
    valid = jnp.concatenate([t.valid for t in tables])
    return Table(columns=cols, valid=valid)


def count_distinct(table: Table, col: str) -> int:
    """Host-side distinct count of a key column (ANALYZE-style statistic)."""
    vals = np.asarray(table[col])[np.asarray(table.valid)]
    return int(np.unique(vals).size)


def table_digest(table: Table) -> str:
    """Content address of the *valid* rows (column names + values).

    Rows are canonicalized by a lexicographic sort first, so the digest is
    a *bag* address: padding, capacity, and row order — which vary with the
    plan that produced the table — never change it.  Used to
    content-address derived artifacts (e.g. the engine's CSR cache, where
    ``extgraph`` and ``ringo`` runs of one model must collide).
    """
    import hashlib

    h = hashlib.sha1()
    data = table.to_numpy()
    names = sorted(data)
    n = len(data[names[0]]) if names else 0
    if n:
        order = np.lexsort(tuple(data[k] for k in reversed(names)))
    else:
        order = np.arange(0)
    for name in names:
        h.update(name.encode())
        h.update(np.ascontiguousarray(data[name][order]).tobytes())
    return h.hexdigest()[:16]
