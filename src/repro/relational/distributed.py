"""Distributed joins: hash-partitioned shard_map execution.

The paper's single-box assumption is the piece that does not scale; the
standard distributed adaptation is: hash-partition both tables on the join
key across the ``data`` mesh axis (one all_to_all each), then run the
shard-local sort-merge join.  JS-MV composes with this naturally — a
materialized view is stored already partitioned by its key, so every reuse
skips the repartition (the distributed version of "materialize once").

The optional Bloom prefilter (kernels/bloom.py) drops probe rows that
cannot match *before* the exchange, cutting the all_to_all payload — the
collective-term optimization recorded in EXPERIMENTS.md §Perf.

Row routing: dest shard = key % n_shards; per-destination capacity is
static (2x fair share by default) with drop-free guarantees asserted by the
caller via :func:`exchange_overflow` (counts, exact).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

try:  # jax>=0.6 moved shard_map
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax import shard_map

from repro.relational.join import sort_merge_join
from repro.relational.table import Table


def _route_local(tbl_cols: Dict[str, jax.Array], valid: jax.Array,
                 key: jax.Array, n: int, cap: int):
    """Scatter local rows into (n, cap, ...) per-destination buffers."""
    dest = jnp.where(valid, key % n, n)             # invalid -> dropped
    order = jnp.argsort(dest, stable=True)
    sdest = dest[order]
    starts = jnp.searchsorted(sdest, jnp.arange(n, dtype=sdest.dtype))
    rank = jnp.arange(dest.shape[0], dtype=jnp.int32) \
        - starts[jnp.clip(sdest, 0, n - 1)].astype(jnp.int32)
    keep = (sdest < n) & (rank < cap)
    slot = jnp.where(keep, sdest.astype(jnp.int32) * cap + rank, n * cap)
    out_cols = {}
    for name, col in tbl_cols.items():
        buf = jnp.zeros((n * cap,), col.dtype).at[slot].set(
            col[order], mode="drop")
        out_cols[name] = buf.reshape(n, cap)
    vbuf = jnp.zeros((n * cap,), bool).at[slot].set(
        keep & valid[order], mode="drop")
    overflow = jnp.sum((sdest < n) & (rank >= cap) & valid[order])
    return out_cols, vbuf.reshape(n, cap), overflow


def repartition_by_key(table: Table, key_col: str, mesh, axis: str = "data",
                       cap_factor: float = 2.0):
    """Hash-partition a row-sharded Table by ``key_col`` over ``axis``.

    Returns (table, overflow_count): the result rows live on the shard
    owning ``key % n``; overflow_count is the number of dropped rows
    (0 unless a shard received > cap_factor x fair share).
    """
    n = mesh.shape[axis]
    local_rows = table.capacity // n
    cap = max(8, int(cap_factor * local_rows / n + 7) // 8 * 8)

    def body(cols, valid):
        cols = {k: v[0] for k, v in cols.items()}   # strip leading shard dim
        valid = valid[0]
        bufs, vbuf, overflow = _route_local(cols, valid, cols[key_col], n,
                                            cap)
        swapped = {k: jax.lax.all_to_all(v, axis, 0, 0)
                   for k, v in bufs.items()}
        vsw = jax.lax.all_to_all(vbuf, axis, 0, 0)
        out_cols = {k: v.reshape(n * cap)[None] for k, v in swapped.items()}
        return out_cols, vsw.reshape(n * cap)[None], \
            jax.lax.psum(overflow, axis)[None]

    # present the table as (shards, local_rows) blocks
    cols2d = {k: v.reshape(n, local_rows) for k, v in table.columns.items()}
    valid2d = table.valid.reshape(n, local_rows)
    specs_in = ({k: PS(axis, None) for k in cols2d}, PS(axis, None))
    specs_out = ({k: PS(axis, None) for k in cols2d}, PS(axis, None),
                 PS(axis))
    fn = shard_map(body, mesh=mesh, in_specs=specs_in,
                   out_specs=specs_out, check_rep=False)
    out_cols, out_valid, overflow = fn(cols2d, valid2d)
    out = Table(columns={k: v.reshape(-1) for k, v in out_cols.items()},
                valid=out_valid.reshape(-1))
    return out, jnp.max(overflow)


def distributed_join(
    left: Table, right: Table, on: Sequence[Tuple[str, str]], mesh,
    axis: str = "data", capacity_per_shard: int = 1 << 14,
    left_partitioned: bool = False, right_partitioned: bool = False,
):
    """Partitioned equi-join: repartition both sides, join shard-locally.

    ``*_partitioned=True`` skips the exchange for inputs already hash-
    partitioned on their key (JS-MV views are stored this way — reuse is
    collective-free).
    """
    lcol, rcol = on[0]
    n = mesh.shape[axis]
    if not left_partitioned:
        left, _ = repartition_by_key(left, lcol, mesh, axis)
    if not right_partitioned:
        right, _ = repartition_by_key(right, rcol, mesh, axis)

    lrows = left.capacity // n
    rrows = right.capacity // n

    def body(lc, lv, rc, rv):
        lt = Table(columns={k: v.reshape(-1) for k, v in lc.items()},
                   valid=lv.reshape(-1))
        rt = Table(columns={k: v.reshape(-1) for k, v in rc.items()},
                   valid=rv.reshape(-1))
        out = sort_merge_join(lt, rt, on=list(on),
                              capacity=capacity_per_shard)
        return ({k: v[None] for k, v in out.columns.items()},
                out.valid[None])

    lcols = {k: v.reshape(n, lrows) for k, v in left.columns.items()}
    rcols = {k: v.reshape(n, rrows) for k, v in right.columns.items()}
    specs_in = ({k: PS(axis, None) for k in lcols}, PS(axis, None),
                {k: PS(axis, None) for k in rcols}, PS(axis, None))
    out_cols_spec = {k: PS(axis, None)
                     for k in list(lcols) + list(rcols)}
    fn = shard_map(body, mesh=mesh, in_specs=specs_in,
                   out_specs=(out_cols_spec, PS(axis, None)),
                   check_rep=False)
    out_cols, out_valid = fn(lcols, left.valid.reshape(n, lrows),
                             rcols, right.valid.reshape(n, rrows))
    return Table(columns={k: v.reshape(-1) for k, v in out_cols.items()},
                 valid=out_valid.reshape(-1))
